"""Subprocess body: IR sharded lowering on 8 fake devices — what the
conformance matrix does NOT cover.

Run by tests/test_ir_multidev.py with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``. The per-backend /
per-program / per-k / per-mesh parity cells live in tests/conformance.py
(driven on multi-device meshes by tests/multidev/_conformance_check.py);
this check keeps:

  * depth-axis sharding (depth-parallel and depth x rows meshes — the
    conformance meshes are pure rows x cols),
  * the fine-mesh regression raises (rows/shard < halo must raise, with
    the shard-the-other-axis remedy in the message),
  * the paper-grid acceptance runs: 64 x 256 x 256 on a depth x rows mesh
    AND on the 2-D rows x cols mesh (k in {1, 2, 3}, both inners, with
    overlap=True bit-matching overlap=False),
  * the multi-field paper-grid acceptance: vadvc and hdiff_coupled on the
    2 x 4 mesh with per-field halo exchange, k in {1, 2, 3},
  * the multi-OUTPUT paper-grid acceptance: shallow_water on the 2 x 4
    mesh, k in {1, 2, 3}, with the merged halo exchange measured-exact
    against the summed wire model (ratio 1.000, 8 permutes).

Exits nonzero (assertion) on any mismatch.
"""

import os

assert "--xla_force_host_platform_device_count=8" in os.environ.get("XLA_FLAGS", "")

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import hdiff
from repro.dist.halo import exchange_row_halos, make_sharded_hdiff
from repro.ir import (
    hdiff_program,
    jacobi2d_9pt_program,
    lower_reference,
    lower_sharded,
    repeat,
)
from repro.launch.mesh import make_mesh

assert len(jax.devices()) == 8

rng = np.random.default_rng(0)
psi = jnp.asarray(rng.standard_normal((8, 32, 16)).astype(np.float32))
want = np.asarray(hdiff(psi, 0.025))
prog = hdiff_program()

# lower_sharded must match lower_reference (and therefore core.hdiff).
ref = np.asarray(lower_reference(prog)(psi))
np.testing.assert_allclose(ref, want, rtol=1e-6, atol=1e-6)

# Depth-axis sharding (absent from the rows x cols conformance meshes):
# plane-per-B-block, depth x rows, and depth x rows x COLS on a 3-axis mesh.
for axes, names, d_ax, r_ax, c_ax in [
    ((8, 1), ("data", "model"), "data", None, None),   # depth-parallel
    ((2, 4), ("data", "model"), "data", "model", None),  # depth x rows
    ((2, 2, 2), ("data", "rows", "cols"), "data", "rows", "cols"),  # full 3-axis
]:
    mesh = make_mesh(axes, names)
    for inner in ("reference", "pallas"):
        fn = lower_sharded(
            prog, mesh, depth_axis=d_ax, row_axis=r_ax, col_axis=c_ax, inner=inner
        )
        got = np.asarray(fn(psi))
        np.testing.assert_allclose(got, ref, rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
        print(f"hdiff {axes} inner={inner} ok")

# Corner-routing regression: ppermute numbers flattened multi-axis pairs in
# MESH declaration order, so a mesh that declares the col axis BEFORE the
# row axis must still route diagonal corners correctly (used to corrupt the
# (R-1)(C-1) internal corner points silently).
mesh_cf = make_mesh((2, 4), ("cols", "rows"))
for inner in ("reference", "pallas"):
    fn = lower_sharded(
        prog, mesh_cf, depth_axis=None, row_axis="rows", col_axis="cols", inner=inner
    )
    np.testing.assert_allclose(
        np.asarray(fn(psi)), want, rtol=1e-6, atol=1e-6,
        err_msg=f"col-first mesh inner={inner}",
    )
print("col-first mesh corner routing ok")

# Temporal blocking on a depth x rows mesh: one depth-k*r exchange per k
# fused sweeps (the rows x cols k-sweeps live in the conformance matrix).
mesh = make_mesh((2, 4), ("data", "model"))
for k in (2, 3):
    pk = repeat(prog, k)
    assert pk.radius == k * prog.radius
    want_k = psi
    for _ in range(k):
        want_k = hdiff(want_k, 0.025)
    want_k = np.asarray(want_k)
    for inner in ("reference", "pallas"):
        fn = lower_sharded(pk, mesh, depth_axis="data", row_axis="model", inner=inner)
        np.testing.assert_allclose(
            np.asarray(fn(psi)), want_k, rtol=1e-6, atol=1e-6,
            err_msg=f"k={k} inner={inner}",
        )
    print(f"temporal depth-x-rows k={k} ok")

# Radius-1 elementary program through a depth-sharded mesh: the exchange
# runs at the inferred halo of 1.
p9 = jacobi2d_9pt_program()
assert p9.radius == 1
from repro.core.stencils import jacobi2d_9pt  # noqa: E402

fn = lower_sharded(p9, mesh, depth_axis="data", row_axis="model", inner="pallas")
np.testing.assert_allclose(
    np.asarray(fn(psi)), np.asarray(jacobi2d_9pt(psi)), rtol=1e-6, atol=1e-6
)
print("jacobi2d_9pt (halo=1) ok")

# Fine-mesh regression: rows/shard < halo must raise, never compute wrong
# interiors — and the message points at the column-shard remedy.
# 32 rows / 8 shards = 4 local rows < 6 (k=3 chain halo).
mesh18 = make_mesh((1, 8), ("data", "model"))
fine = lower_sharded(repeat(prog, 3), mesh18, depth_axis=None, row_axis="model")
try:
    fine(psi)
    raise SystemExit("fine-mesh k-step lower_sharded did not raise")
except ValueError as e:
    assert "halo" in str(e) and "shard columns" in str(e), e
# The SAME grid succeeds when the excess shards go to columns instead:
# the remedy the error names. 16 cols / 8 shards is still too fine for
# halo 6, but 2 rows x 4 cols works (32/2=16 >= 6, 16/4=4 < 6 -> use 2x2
# with depth): verify the smallest legal 2-D split of the k=3 chain.
meshrc = make_mesh((2, 2, 2), ("data", "rows", "cols"))
fn = lower_sharded(
    repeat(prog, 3), meshrc, depth_axis="data", row_axis="rows", col_axis="cols",
    inner="reference",
)
want3 = psi
for _ in range(3):
    want3 = hdiff(want3, 0.025)
np.testing.assert_allclose(np.asarray(fn(psi)), np.asarray(want3), rtol=1e-6, atol=1e-6)
print("fine-mesh remedy (shard cols) ok")

# An UNSHARDED axis thinner than the halo is fine (zero pads, no neighbour
# sourcing): the planner-feasible 1x8 split of a 4-row grid lowers and, with
# every row inside the radius-6 ring, passes the input through unchanged.
from repro.ir import plan_partition  # noqa: E402

thin = jnp.asarray(rng.standard_normal((4, 4, 256)).astype(np.float32))
p3 = repeat(prog, 3)
plan = plan_partition(p3, *thin.shape, 8)
assert plan.mesh_shape == (1, 8), plan
np.testing.assert_array_equal(
    np.asarray(lower_sharded(p3, mesh_shape=plan.mesh_shape, inner="reference")(thin)),
    np.asarray(thin),
)
print("thin unsharded-row axis ok (planner-consistent)")

# Same guard on make_sharded_hdiff: 8 rows / 8 shards = 1 local row < HALO=2.
psi8 = jnp.asarray(rng.standard_normal((2, 8, 16)).astype(np.float32))
try:
    make_sharded_hdiff(mesh18, depth_axis=None, row_axis="model")(psi8)
    raise SystemExit("fine-mesh make_sharded_hdiff did not raise")
except ValueError as e:
    assert "halo" in str(e), e
# And on exchange_row_halos itself (the defence the callers rely on): a
# 4-row shard cannot source a 6-row band from one neighbour.
try:
    jax.shard_map(
        lambda b: exchange_row_halos(b, "model", 8, halo=6),
        mesh=mesh18,
        in_specs=(jax.sharding.PartitionSpec(None, "model", None),),
        out_specs=jax.sharding.PartitionSpec(None, "model", None),
        check_vma=False,
    )(psi)
    raise SystemExit("fine-mesh exchange_row_halos did not raise")
except ValueError as e:
    assert "ppermute" in str(e) or "halo" in str(e), e
print("fine-mesh raise ok")

# Acceptance: the paper grid (64 x 256 x 256). First the PR 3 depth x rows
# run, then the ISSUE 4 acceptance — the 2 x 4 rows x cols mesh, k in
# {1, 2, 3}, both inners, overlap=True bit-matching overlap=False.
paper = jnp.asarray(rng.standard_normal((64, 256, 256)).astype(np.float32))
mesh = make_mesh((4, 2), ("data", "model"))
fn = lower_sharded(prog, mesh, depth_axis="data", row_axis="model", inner="reference")
np.testing.assert_allclose(
    np.asarray(fn(paper)), np.asarray(hdiff(paper, 0.025)), rtol=1e-6, atol=1e-6
)
print("paper-grid sharded ok")

want_k = np.asarray(paper)
for k in (1, 2, 3):
    want_k = np.asarray(hdiff(jnp.asarray(want_k), 0.025))  # k applications total
    pk = repeat(prog, k)
    ref_k = np.asarray(lower_reference(pk)(paper))
    np.testing.assert_allclose(ref_k, want_k, rtol=1e-6, atol=1e-6)
    for inner in ("reference", "pallas"):
        fn = lower_sharded(pk, mesh_shape=(2, 4), inner=inner)
        got = np.asarray(fn(paper))
        np.testing.assert_allclose(
            got, ref_k, rtol=1e-6, atol=1e-6, err_msg=f"paper 2x4 k={k} {inner}"
        )
        overlap_inner = inner == "reference" or k == 2
        if overlap_inner:
            fo = lower_sharded(pk, mesh_shape=(2, 4), inner=inner, overlap=True)
            np.testing.assert_array_equal(
                np.asarray(fo(paper)), got,
                err_msg=f"paper 2x4 overlap k={k} {inner}",
            )
    print(f"paper-grid 2x4 k={k} ok (both inners, overlap bit-match)")

# Multi-field acceptance on the paper grid: vadvc (both fields exchange a
# halo) and hdiff_coupled (coeff exchanges nothing at k=1, 2(k-1) beyond)
# on the 2 x 4 rows x cols mesh, k in {1, 2, 3}, vs the composed reference
# oracle — the ISSUE 5 acceptance runs. The Pallas inner runs at k=2 to
# bound compile time (its full k sweep lives in the conformance matrix).
from repro.ir import (  # noqa: E402
    hdiff_coupled_program,
    smagorinsky_coeff,
    vadvc_program,
)

mf_cases = {
    "vadvc": (vadvc_program(), {
        "s": paper,
        "w": jnp.asarray(rng.standard_normal(paper.shape).astype(np.float32)),
    }),
    "hdiff_coupled": (hdiff_coupled_program(), {
        "u": paper,
        "coeff": jnp.asarray(smagorinsky_coeff(rng.standard_normal(paper.shape))),
    }),
}
for name, (mprog, arrs) in mf_cases.items():
    for k in (1, 2, 3):
        pk = repeat(mprog, k)
        ref_k = np.asarray(lower_reference(pk)(arrs))
        inners = ("reference", "pallas") if k == 2 else ("reference",)
        for inner in inners:
            fn = lower_sharded(pk, mesh_shape=(2, 4), inner=inner)
            np.testing.assert_allclose(
                np.asarray(fn(arrs)), ref_k, rtol=1e-6, atol=1e-6,
                err_msg=f"paper 2x4 {name} k={k} {inner}",
            )
        fo = lower_sharded(pk, mesh_shape=(2, 4), inner="reference", overlap=True)
        np.testing.assert_array_equal(
            np.asarray(fo(arrs)),
            np.asarray(lower_sharded(pk, mesh_shape=(2, 4), inner="reference")(arrs)),
            err_msg=f"paper 2x4 {name} overlap k={k}",
        )
        print(f"paper-grid 2x4 {name} k={k} ok (overlap bit-match)")

# Multi-OUTPUT acceptance on the paper grid (the ISSUE 8 run): the coupled
# shallow-water system {u, v, h} on the 2 x 4 rows x cols mesh, k in
# {1, 2, 3} (Pallas inner at k=2 to bound compile time), overlap=True
# bit-matching overlap=False per output field — and the wire model held
# measured-exact: ONE merged exchange per k fused sweeps whose per-chip
# collective-permute bytes equal program_halo_exchange_bytes_per_shard at
# ratio 1.000, in exactly 8 permutes (2 row bands + 2 col bands + 4
# corners; a sequential per-field exchange would issue 24).
from repro.dist.halo import (  # noqa: E402
    measured_collective_permute_bytes,
    program_halo_exchange_bytes_per_shard,
)
from repro.ir import shallow_water_program  # noqa: E402

sw = shallow_water_program()
sw_arrs = {
    "u": paper,
    "v": jnp.asarray(rng.standard_normal(paper.shape).astype(np.float32)),
    "h": jnp.asarray(rng.standard_normal(paper.shape).astype(np.float32)),
}
for k in (1, 2, 3):
    pk = repeat(sw, k)
    assert pk.output_radii() == {"u": k, "v": k, "h": k}, pk.output_radii()
    ref_k = lower_reference(pk)(sw_arrs)
    ref_k = {f: np.asarray(a) for f, a in ref_k.items()}
    inners = ("reference", "pallas") if k == 2 else ("reference",)
    for inner in inners:
        fn = lower_sharded(pk, mesh_shape=(2, 4), inner=inner)
        got = fn(sw_arrs)
        for f in ref_k:
            np.testing.assert_allclose(
                np.asarray(got[f]), ref_k[f], rtol=1e-6, atol=1e-6,
                err_msg=f"paper 2x4 shallow_water k={k} {inner} [{f}]",
            )
    base = lower_sharded(pk, mesh_shape=(2, 4), inner="reference")
    fo = lower_sharded(pk, mesh_shape=(2, 4), inner="reference", overlap=True)
    got_base, got_over = base(sw_arrs), fo(sw_arrs)
    for f in ref_k:
        np.testing.assert_array_equal(
            np.asarray(got_over[f]), np.asarray(got_base[f]),
            err_msg=f"paper 2x4 shallow_water overlap k={k} [{f}]",
        )
    # Wire acceptance: the merged exchange is measured-exact vs the
    # summed per-output model, in 8 permutes total.
    measured, n_permutes = measured_collective_permute_bytes(base, sw_arrs)
    model = program_halo_exchange_bytes_per_shard(
        pk, 64, 128, 64, row_sharded=True, col_sharded=True
    )
    if k == 1:
        # The sequential per-field baseline (merge_exchange=False) moves the
        # SAME bytes in 3x the permutes and BIT-matches the merged path.
        seq = lower_sharded(pk, mesh_shape=(2, 4), inner="reference",
                            merge_exchange=False)
        got_seq = seq(sw_arrs)
        for f in ref_k:
            np.testing.assert_array_equal(
                np.asarray(got_seq[f]), np.asarray(got_base[f]),
                err_msg=f"merged != sequential exchange [{f}]",
            )
        seq_bytes, seq_permutes = measured_collective_permute_bytes(seq, sw_arrs)
        assert seq_bytes == measured, (seq_bytes, measured)
        assert seq_permutes == 24, seq_permutes
        print("merged-vs-sequential exchange: bit-match, same bytes, 8 vs 24 permutes")
    assert measured == model, (
        f"shallow_water k={k} merged wire bytes: measured {measured} != "
        f"model {model} (ratio {measured / model:.3f})"
    )
    assert n_permutes == 8, (
        f"shallow_water k={k}: expected ONE merged exchange (8 permutes), "
        f"got {n_permutes}"
    )
    print(
        f"paper-grid 2x4 shallow_water k={k} ok (overlap bit-match; merged "
        f"exchange {measured:.0f} B/chip == model, ratio 1.000, 8 permutes)"
    )

print("ALL_OK")
