"""Subprocess body: IR sharded lowering == reference on 8 fake devices.

Run by tests/test_ir_multidev.py with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``. Covers both inner
backends (reference evaluator and Pallas-kernel-inside-shard_map) at the
graph-INFERRED halo — radius 2 for hdiff, radius 1 for the elementary
9-point program — plus the paper-grid acceptance run.
Exits nonzero (assertion) on any mismatch.
"""

import os

assert "--xla_force_host_platform_device_count=8" in os.environ.get("XLA_FLAGS", "")

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import hdiff, hdiff_simple
from repro.core.stencils import jacobi2d_9pt
from repro.dist.halo import exchange_row_halos, make_sharded_hdiff
from repro.ir import (
    hdiff_program,
    jacobi2d_9pt_program,
    lower_reference,
    lower_sharded,
    repeat,
)
from repro.launch.mesh import make_mesh

assert len(jax.devices()) == 8

rng = np.random.default_rng(0)
psi = jnp.asarray(rng.standard_normal((8, 32, 16)).astype(np.float32))
want = np.asarray(hdiff(psi, 0.025))
prog = hdiff_program()

# lower_sharded must match lower_reference (and therefore core.hdiff).
ref = np.asarray(lower_reference(prog)(psi))
np.testing.assert_allclose(ref, want, rtol=1e-6, atol=1e-6)

for axes, d_ax, r_ax in [
    ((8, 1), "data", None),       # depth-parallel: plane-per-B-block
    ((2, 4), "data", "model"),    # depth x rows with radius-2 halo exchange
    ((1, 8), None, "model"),      # rows barely larger than the halo
]:
    mesh = make_mesh(axes, ("data", "model"))
    for inner in ("reference", "pallas"):
        fn = lower_sharded(prog, mesh, depth_axis=d_ax, row_axis=r_ax, inner=inner)
        got = np.asarray(fn(psi))
        np.testing.assert_allclose(got, ref, rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
        print(f"hdiff {axes} inner={inner} ok")

# Unlimited variant.
mesh = make_mesh((2, 4), ("data", "model"))
fn = lower_sharded(hdiff_program(limit=False), mesh, depth_axis="data", row_axis="model")
np.testing.assert_allclose(
    np.asarray(fn(psi)), np.asarray(hdiff_simple(psi, 0.025)), rtol=1e-6, atol=1e-6
)
print("hdiff-simple ok")

# Radius-1 elementary program: the exchange runs at the inferred halo of 1.
p9 = jacobi2d_9pt_program()
assert p9.radius == 1
fn = lower_sharded(p9, mesh, depth_axis="data", row_axis="model", inner="pallas")
np.testing.assert_allclose(
    np.asarray(fn(psi)), np.asarray(jacobi2d_9pt(psi)), rtol=1e-6, atol=1e-6
)
print("jacobi2d_9pt (halo=1) ok")

# Temporal blocking: the k-step sharded lowering exchanges a depth-k*r halo
# ONCE per k fused sweeps and must bit-match k composed applications.
mesh = make_mesh((2, 4), ("data", "model"))
for k in (1, 2, 3):
    pk = repeat(prog, k)
    assert pk.radius == k * prog.radius
    want_k = psi
    for _ in range(k):
        want_k = hdiff(want_k, 0.025)
    want_k = np.asarray(want_k)
    for inner in ("reference", "pallas"):
        fn = lower_sharded(pk, mesh, depth_axis="data", row_axis="model", inner=inner)
        np.testing.assert_allclose(
            np.asarray(fn(psi)), want_k, rtol=1e-6, atol=1e-6,
            err_msg=f"k={k} inner={inner}",
        )
    print(f"temporal k={k} ok")

# Fine-mesh regression: rows/shard < halo must raise, never compute wrong
# interiors. 32 rows / 8 shards = 4 local rows < 6 (k=3 chain halo).
mesh18 = make_mesh((1, 8), ("data", "model"))
fine = lower_sharded(repeat(prog, 3), mesh18, depth_axis=None, row_axis="model")
try:
    fine(psi)
    raise SystemExit("fine-mesh k-step lower_sharded did not raise")
except ValueError as e:
    assert "halo" in str(e), e
# Same guard on make_sharded_hdiff: 8 rows / 8 shards = 1 local row < HALO=2.
psi8 = jnp.asarray(rng.standard_normal((2, 8, 16)).astype(np.float32))
try:
    make_sharded_hdiff(mesh18, depth_axis=None, row_axis="model")(psi8)
    raise SystemExit("fine-mesh make_sharded_hdiff did not raise")
except ValueError as e:
    assert "halo" in str(e), e
# And on exchange_row_halos itself (the defence the callers rely on): a
# 4-row shard cannot source a 6-row band from one neighbour.
try:
    jax.shard_map(
        lambda b: exchange_row_halos(b, "model", 8, halo=6),
        mesh=mesh18,
        in_specs=(jax.sharding.PartitionSpec(None, "model", None),),
        out_specs=jax.sharding.PartitionSpec(None, "model", None),
        check_vma=False,
    )(psi)
    raise SystemExit("fine-mesh exchange_row_halos did not raise")
except ValueError as e:
    assert "ppermute" in str(e) or "halo" in str(e), e
print("fine-mesh raise ok")

# Acceptance: the paper grid (64 x 256 x 256) on the full 8-device mesh,
# single-step and k=2 temporal-blocked.
paper = jnp.asarray(rng.standard_normal((64, 256, 256)).astype(np.float32))
mesh = make_mesh((4, 2), ("data", "model"))
fn = lower_sharded(prog, mesh, depth_axis="data", row_axis="model", inner="reference")
np.testing.assert_allclose(
    np.asarray(fn(paper)), np.asarray(hdiff(paper, 0.025)), rtol=1e-6, atol=1e-6
)
print("paper-grid sharded ok")
fn2 = lower_sharded(
    repeat(prog, 2), mesh, depth_axis="data", row_axis="model", inner="reference"
)
np.testing.assert_allclose(
    np.asarray(fn2(paper)),
    np.asarray(hdiff(hdiff(paper, 0.025), 0.025)),
    rtol=1e-6,
    atol=1e-6,
)
print("paper-grid temporal k=2 ok")

print("ALL_OK")
