"""Subprocess body: the gradient-conformance slice of the matrix on ONE
multi-device mesh (run by tests/test_grad_conformance.py with XLA_FLAGS
forcing the fake-device count).

Three layers per mesh:

  * jax.grad of the ``sharded-reference`` differentiable lowering vs
    jax.grad of ``lower_reference`` for EVERY matrix program at every k —
    the derived adjoint sweeps (``repro.ir.autodiff``) running through
    ``lower_sharded(..., boundary="zero")`` with the real halo exchange;
  * the same for the ``sharded-pallas`` inner on a program subset (the
    in-shard adjoint kernel is identical across programs; the subset bounds
    interpret-mode compile time);
  * the backward WIRE model: measured collective-permute bytes of a
    value-and-grad step must equal ``gradient_halo_exchange_bytes_per_shard``
    EXACTLY (ratio 1.000) — the paper's measured-vs-model discipline
    extended to the adjoint. On the 2x4 mesh the paper grid (64x256x256)
    is asserted too.

Prints DEVICES_UNAVAILABLE (exit 3) when the device count cannot back the
mesh — the caller converts that into a pytest skip, which the CI multidev
job's skip gate turns into a failure.
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax  # noqa: E402

ap = argparse.ArgumentParser()
ap.add_argument("--mesh", required=True, help="RxC, e.g. 2x4")
args = ap.parse_args()
R, C = (int(s) for s in args.mesh.split("x"))

if len(jax.devices()) < R * C:
    print(f"DEVICES_UNAVAILABLE mesh {args.mesh} needs {R * C} devices, "
          f"have {len(jax.devices())}")
    sys.exit(3)

import jax.numpy as jnp  # noqa: E402

from conformance import (  # noqa: E402
    GRID,
    KS,
    PROGRAMS,
    assert_grad_case,
    build_grad,
    grad_loss,
    make_fields,
    make_loss_weights,
)
from repro.dist.halo import (  # noqa: E402
    gradient_halo_exchange_bytes_per_shard,
    measured_collective_permute_bytes,
)
from repro.ir import hdiff_program, repeat  # noqa: E402
from repro.ir.lower_reference import lower_reference  # noqa: E402

mesh = (R, C)

# Layer 1: full roster, sharded-reference inner, every k.
for name in sorted(PROGRAMS):
    for k in KS:
        assert_grad_case(name, "sharded-reference", k, mesh)
        print(f"grad {name} sharded-reference k={k} ok")

# Layer 2: Pallas inner on the conformance subset (single-input chain,
# coupled multi-output system, multi-field coefficient workload).
for name in ("hdiff", "shallow_water", "hdiff_coupled"):
    for k in (1, 2):
        assert_grad_case(name, "sharded-pallas", k, mesh)
        print(f"grad {name} sharded-pallas k={k} ok")

# Layer 3: backward wire bytes, measured == model EXACTLY (ratio 1.000).
def assert_wire(program, x, label, *, depth, rows, cols):
    fn = build_grad(program, "sharded-reference", mesh)
    w_ref = lower_reference(program)(x)
    if isinstance(w_ref, dict):
        w = {f: jnp.ones_like(a) for f, a in w_ref.items()}
    else:
        w = jnp.ones_like(w_ref)
    loss = grad_loss(fn, w)

    def vg(x):
        # Returning the primal too keeps the forward alive (grad-only
        # output lets XLA dead-code the fwd and undercount permutes).
        return jax.value_and_grad(loss)(x)

    measured, count = measured_collective_permute_bytes(vg, x)
    model = gradient_halo_exchange_bytes_per_shard(
        program, depth, rows, cols, mesh_shape=mesh)
    assert measured == model, (
        f"{label}: grad wire measured={measured} model={model} "
        f"ratio={measured / model:.3f} permutes={count}"
    )
    print(f"grad wire {label} ratio=1.000 ok ({model} bytes/chip)")


for name, k in (("hdiff", 1), ("hdiff", 2), ("hdiff", 3),
                ("hdiff_coupled", 2), ("shallow_water", 2)):
    p = repeat(PROGRAMS[name](), k)
    assert_wire(p, make_fields(name), f"{name} k={k} mesh={args.mesh}",
                depth=GRID[0], rows=GRID[1], cols=GRID[2])

# Paper-grid acceptance on the 2x4 rows x cols mesh: hdiff 64x256x256,
# gradient conformance AND exact backward wire bytes.
if mesh == (2, 4):
    pgrid = (64, 256, 256)
    p = hdiff_program()
    x = jax.random.normal(jax.random.PRNGKey(0), pgrid, jnp.float32) * 0.1
    wv = jax.random.normal(jax.random.PRNGKey(1), pgrid, jnp.float32)
    gref = jax.grad(grad_loss(lower_reference(p), wv))(x)
    got = jax.grad(grad_loss(build_grad(p, "sharded-reference", mesh), wv))(x)
    rel = float(jnp.abs(got - gref).max()) / float(jnp.abs(gref).max())
    assert rel < 1e-5, f"paper-grid grad relerr {rel:.3e}"
    assert_wire(p, x, "paper-grid hdiff 64x256x256 2x4",
                depth=pgrid[0], rows=pgrid[1], cols=pgrid[2])
    print(f"paper-grid grad 2x4 ok (relerr={rel:.1e})")

# The loss weights helper must have been exercised with the real programs
# (guards against the oracle cache silently diverging from the cells).
assert make_loss_weights("hdiff", 1) is not None

print("ALL_OK")
