"""Subprocess body: shard_map expert-parallel MoE == local MoE, 8 devices.

Also checks the full qwen3-family smoke model end-to-end under a mesh, and
that gradients flow through the shard_map path.
"""

import os

assert "--xla_force_host_platform_device_count=8" in os.environ.get("XLA_FLAGS", "")

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.launch.mesh import make_mesh
from repro.models import layers as L
from repro.models import build_lm, lm_loss

cfg = get_smoke_config("qwen3-moe-235b-a22b")
# dropless so local (unsharded) and sharded dispatch agree exactly;
# f32 for a tight comparison
cfg = dataclasses.replace(cfg, compute_dtype="float32", capacity_factor=64.0)

p, _ = L.init_moe(cfg, jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model), jnp.float32)

y_ref, aux_ref = L._apply_moe_local(cfg, p, x)

mesh = make_mesh((2, 4), ("data", "model"))
with jax.set_mesh(mesh):
    y_sh, aux_sh = jax.jit(lambda p, x: L.apply_moe_sharded(cfg, p, x))(p, x)

np.testing.assert_allclose(np.asarray(y_sh), np.asarray(y_ref), rtol=2e-4, atol=2e-4)
np.testing.assert_allclose(float(aux_sh), float(aux_ref), rtol=1e-3)
print("moe sharded == local ok")

# arctic family: dense residual branch
cfg2 = dataclasses.replace(
    get_smoke_config("arctic-480b"), compute_dtype="float32", capacity_factor=64.0
)
p2, _ = L.init_moe(cfg2, jax.random.PRNGKey(2))
x2 = jax.random.normal(jax.random.PRNGKey(3), (4, 8, cfg2.d_model), jnp.float32)
y2_ref, _ = L._apply_moe_local(cfg2, p2, x2)
with jax.set_mesh(mesh):
    y2_sh, _ = jax.jit(lambda p, x: L.apply_moe_sharded(cfg2, p, x))(p2, x2)
np.testing.assert_allclose(np.asarray(y2_sh), np.asarray(y2_ref), rtol=2e-4, atol=2e-4)
print("moe dense-residual ok")

# end-to-end: loss + grads through the sharded MoE inside the scan
params, _ = build_lm(cfg, jax.random.PRNGKey(4))
batch = {
    "tokens": jax.random.randint(jax.random.PRNGKey(5), (4, 16), 0, cfg.vocab_size),
    "labels": jax.random.randint(jax.random.PRNGKey(6), (4, 16), 0, cfg.vocab_size),
}
loss_plain, _ = lm_loss(cfg, params, batch)
with jax.set_mesh(mesh):
    (loss_sh, _), grads = jax.jit(
        jax.value_and_grad(lambda p: lm_loss(cfg, p, batch), has_aux=True)
    )(params)
np.testing.assert_allclose(float(loss_sh), float(loss_plain), rtol=2e-4)
gnorm = float(
    jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads)))
)
assert np.isfinite(gnorm) and gnorm > 0
print("e2e moe loss+grads ok")
print("ALL_OK")
