"""Subprocess body: the observability layer on 8 fake devices.

Run by tests/test_obs.py with XLA_FLAGS forcing 8 host devices and
REPRO_METRICS=1 in the environment, so the metrics registry is installed
at import time (the env-auto-enable path) and every instrumented layer is
live. Asserts:

  * ``lower_sharded`` records its per-call timer/counter and the per-field
    halo byte-model counters, for single-field (hdiff, k=1 and k=2) and
    multi-field (vadvc) programs;
  * ``wire_drift_report`` finds measured == model (ratio within
    [0.99, 1.01], in practice exactly 1.0) for every case, and records the
    drift gauges with zero drift flags;
  * instrumented results BIT-match the uninstrumented ones (metrics off) —
    instrumentation must not perturb the computation.

Prints ALL_OK on success.
"""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax

assert len(jax.devices()) == 8, jax.devices()

import numpy as np
import jax.numpy as jnp

from repro.dist import wire_drift_report
from repro.ir import hdiff_program, lower_sharded, repeat, vadvc_program
from repro.launch.mesh import make_mesh
from repro.obs import metrics

assert metrics.enabled(), "REPRO_METRICS=1 must auto-enable the registry"
reg = metrics.current()

depth, rows, cols = 8, 64, 64
dsh, rsh = 2, 4
mesh = make_mesh((dsh, rsh), ("data", "model"))
rng = np.random.default_rng(0)
psi = jnp.asarray(rng.standard_normal((depth, rows, cols)).astype(np.float32))

cases = [
    ("hdiff_k1", repeat(hdiff_program(), 1), psi),
    ("hdiff_k2", repeat(hdiff_program(), 2), psi),
    (
        "vadvc_k1",
        repeat(vadvc_program(), 1),
        {"s": psi, "w": jnp.asarray(rng.standard_normal(psi.shape).astype(np.float32))},
    ),
]

for label, prog, x in cases:
    reg.reset()
    fn = lower_sharded(prog, mesh, depth_axis="data", row_axis="model",
                       inner="reference")
    got = np.asarray(fn(x))

    # Instrumentation must not perturb the numbers: metrics-off bit-match.
    prev = metrics.current()
    metrics.disable()
    try:
        fn_off = lower_sharded(prog, mesh, depth_axis="data", row_axis="model",
                               inner="reference")
        want = np.asarray(fn_off(x))
    finally:
        metrics.enable(prev)
    assert (got == want).all(), f"{label}: instrumented result diverged"

    snap = reg.snapshot()
    name = f"ir.lower_sharded.{prog.name}"
    assert snap["counters"].get(f"{name}.calls") == 1.0, (label, snap["counters"])
    assert name in snap["timers"], (label, sorted(snap["timers"]))
    assert snap["counters"].get("halo.exchange_rounds", 0) >= 1.0, (
        label, snap["counters"])
    model_counters = {
        k: v for k, v in snap["counters"].items()
        if k.startswith("halo.model_bytes.")
    }
    assert model_counters, f"{label}: no per-field halo model counters"

    drift = wire_drift_report(
        prog, fn, x,
        local_depth=depth // dsh, local_rows=rows // rsh, local_cols=cols,
        row_sharded=True, col_sharded=False, name=f"halo.wire.{label}",
    )
    assert 0.99 <= drift.ratio <= 1.01, drift.describe()
    assert drift.ok, drift.describe()
    assert reg.counters.get(f"halo.wire.{label}.drift_flags", 0) == 0
    assert reg.gauges[f"halo.wire.{label}.ratio"] == drift.ratio
    # The model counter recorded at call time matches the wire model per
    # exchange round (single-field: one field; vadvc: sum of both fields).
    rounds = reg.counters["halo.exchange_rounds"]
    per_round_model = sum(model_counters.values()) / rounds
    assert per_round_model == drift.model, (
        label, per_round_model, drift.model, model_counters)
    print(f"{label}: ratio={drift.ratio:.6f} model_bytes={drift.model} "
          f"counters={sorted(model_counters)}")

print("ALL_OK")
