"""Subprocess body: sharded hdiff == single-device hdiff on 8 fake devices.

Run by tests/test_dist.py with XLA_FLAGS=--xla_force_host_platform_device_count=8.
Exits nonzero (assertion) on any mismatch.
"""

import os

assert "--xla_force_host_platform_device_count=8" in os.environ.get("XLA_FLAGS", "")

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import hdiff, hdiff_simple
from repro.dist import make_sharded_hdiff, reduce_gradients
from repro.launch.mesh import make_mesh

assert len(jax.devices()) == 8

rng = np.random.default_rng(0)
psi = jnp.asarray(rng.standard_normal((8, 32, 16)).astype(np.float32))
want = np.asarray(hdiff(psi, 0.025))

# --- depth-parallel over all 8 devices (paper's plane-per-B-block) ----------
mesh = make_mesh((8, 1), ("data", "model"))
fn = make_sharded_hdiff(mesh, depth_axis="data", row_axis=None)
got = np.asarray(fn(psi))
np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
print("depth-parallel ok")

# --- row decomposition with halo exchange (4-way) ----------------------------
mesh = make_mesh((2, 4), ("data", "model"))
fn = make_sharded_hdiff(mesh, depth_axis="data", row_axis="model")
got = np.asarray(fn(psi))
np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
print("row-halo ok")

# --- row decomposition, 8-way, rows barely larger than halo ------------------
mesh = make_mesh((1, 8), ("data", "model"))
fn = make_sharded_hdiff(mesh, depth_axis=None, row_axis="model")
got = np.asarray(fn(psi))
np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
print("row-halo-8 ok")

# --- simple (unlimited) variant ----------------------------------------------
want_s = np.asarray(hdiff_simple(psi, 0.025))
mesh = make_mesh((2, 4), ("data", "model"))
fn = make_sharded_hdiff(mesh, depth_axis="data", row_axis="model", limit=False)
np.testing.assert_allclose(np.asarray(fn(psi)), want_s, rtol=1e-6, atol=1e-6)
print("simple ok")

# --- gradient compression all-reduce -----------------------------------------
mesh = make_mesh((8,), ("data",))
grads = {"w": jnp.asarray(rng.standard_normal((8, 4, 4)).astype(np.float32))}


def reduce_local(g):
    return reduce_gradients(g, ("data",), method="bf16")


red = jax.jit(
    jax.shard_map(
        reduce_local,
        mesh=mesh,
        in_specs=({"w": jax.sharding.PartitionSpec("data", None, None)},),
        out_specs={"w": jax.sharding.PartitionSpec("data", None, None)},
    )
)(grads)
want_mean = np.asarray(grads["w"]).astype(np.float32).mean(axis=0)
got_mean = np.asarray(red["w"])[0]
np.testing.assert_allclose(got_mean, want_mean, rtol=2e-2, atol=2e-2)
print("compress-reduce ok")

print("ALL_OK")
