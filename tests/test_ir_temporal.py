"""Temporal blocking: repeat/compose analysis + k-step boundary semantics.

Per-backend k-step parity cells (k in {1, 2, 3} x every backend x every
mesh) live in the conformance matrix (tests/conformance.py); this file
keeps the graph-level composition invariants, the boundary-ring semantics
that distinguish stepped from pure-DAG execution, the 1-D chain path, and
the paper-grid acceptance run.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import hdiff
from repro.core.stencils import jacobi1d
from repro.ir import (
    StencilProgram,
    affine,
    hdiff_program,
    jacobi1d_program,
    jacobi2d_5pt_program,
    laplacian_program,
    lower_pallas,
    lower_reference,
    lower_sharded,
    repeat,
)
from repro.launch.mesh import make_mesh

RNG = np.random.default_rng(23)


def _grid(*shape):
    return jnp.asarray(RNG.standard_normal(shape).astype(np.float32))


def _composed(fn, x, k):
    for _ in range(k):
        x = fn(x)
    return np.asarray(x)


# --- graph-level composition -------------------------------------------------


def test_repeat_radius_and_steps_scale():
    p = hdiff_program()
    for k in (1, 2, 3, 5):
        pk = repeat(p, k)
        assert pk.radius == k * p.radius
        assert pk.steps == k
        assert len(pk.chain) == k
        assert all(c is p for c in pk.chain)
    assert repeat(p, 1) is p


def test_compose_chains_heterogeneous_programs():
    a, b = laplacian_program(), jacobi2d_5pt_program()
    ab = a.compose(b)
    assert ab.radius == a.radius + b.radius == 2
    assert ab.steps == 2
    assert ab.chain == (a, b)
    # Deeper stacking keeps field names unique and radii additive.
    abab = ab.compose(ab)
    assert abab.radius == 4 and abab.steps == 4
    names = [op.name for op in abab.ops]
    assert len(names) == len(set(names))


def test_compose_validation():
    p = hdiff_program()
    two_in = StencilProgram(
        "two", ["a", "b"], [affine("out", "a", {(0, 0): 1.0})]
    )
    # hdiff has no "b" input to share, so feeding two_in after it fails.
    with pytest.raises(ValueError, match="shared field"):
        p.compose(two_in)
    with pytest.raises(ValueError, match="ndim"):
        p.compose(jacobi1d_program())
    with pytest.raises(ValueError, match="positive int"):
        repeat(p, 0)
    # Multi-field self-composition is legal: the passthrough input evolves,
    # the shared field feeds both sweeps.
    two_k = repeat(two_in, 2)
    assert two_k.steps == 2 and two_k.inputs == ("a", "b")
    assert two_k.field_radii() == {"a": 0, "b": 0}


def test_repeat_per_step_accounting_divides_by_k():
    p = hdiff_program()
    points = 64 * 256 * 256
    for k in (1, 2, 4):
        pk = repeat(p, k)
        # One fused residency still moves (inputs + output) once...
        assert pk.fused_bytes(points) == p.fused_bytes(points)
        # ...so per-simulated-step traffic divides by k.
        assert pk.fused_bytes_per_step(points) == p.fused_bytes(points) / k


# --- k-step 1-D chain path (outside the 2-D conformance matrix) ---------------


@pytest.mark.parametrize("k", [2, 3])
def test_kstep_jacobi1d_matches_composed(k):
    x1 = _grid(3, 24)
    want1 = _composed(jacobi1d, x1, k)
    got1 = np.asarray(lower_pallas(repeat(jacobi1d_program(), k), interpret=True)(x1))
    np.testing.assert_allclose(got1, want1, rtol=1e-6, atol=1e-6)


def test_kstep_block_rows_down_to_chain_halo():
    """The three-slab trick only needs block_rows >= k*r (one neighbour
    block sources the whole band); the smallest legal tile must agree."""
    x = _grid(1, 16, 12)
    want = _composed(lambda a: hdiff(a, 0.025), x, 2)
    pk = repeat(hdiff_program(), 2)  # chain halo 4
    got = np.asarray(lower_pallas(pk, block_rows=4, interpret=True)(x))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
    with pytest.raises(ValueError, match="inferred row halo"):
        lower_pallas(pk, block_rows=2, interpret=True)(x)


def test_kstep_boundary_ring_passthrough_per_sweep():
    """The OUTER radius-r ring holds the input after every sweep; rows in
    [r, k*r) are computed (from ring passthrough values), NOT passed
    through — the distinction between stepped and pure-DAG semantics."""
    x = _grid(1, 20, 20)
    k = 2
    got = np.asarray(lower_pallas(repeat(hdiff_program(), k), interpret=True)(x))
    want = _composed(lambda a: hdiff(a, 0.025), x, k)
    np.testing.assert_array_equal(got[:, :2, :], np.asarray(x[:, :2, :]))
    np.testing.assert_array_equal(got[:, -2:, :], np.asarray(x[:, -2:, :]))
    # Rows 2..3 differ from the input (they are computed at sweep 2).
    assert np.abs(got[:, 2:4, 2:-2] - np.asarray(x[:, 2:4, 2:-2])).max() > 0
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@pytest.mark.slow
def test_kstep_paper_grid_acceptance():
    """k in {1,2,3} on the paper's 64x256x256 domain, reference + Pallas."""
    x = _grid(64, 256, 256)
    want = np.asarray(x)
    for k in (1, 2, 3):
        want = np.asarray(hdiff(jnp.asarray(want), 0.025))
        pk = repeat(hdiff_program(), k)
        got_ref = np.asarray(lower_reference(pk)(x))
        np.testing.assert_allclose(got_ref, want, rtol=1e-6, atol=1e-6)
        got_pl = np.asarray(lower_pallas(pk, interpret=True)(x))
        np.testing.assert_allclose(got_pl, want, rtol=1e-6, atol=1e-6)


# --- k-step sharded lowering (1-device mesh; 8-device in tests/multidev) -----


def test_kstep_sharded_uses_chain_halo_in_validation():
    """The rows/shard floor is the CHAIN radius k*r: the k-step exchange
    needs the full band from the immediate neighbour."""
    mesh = make_mesh((1, 1), ("data", "model"))
    fn = lower_sharded(repeat(hdiff_program(), 3), mesh, row_axis="model")
    # 1 row shard: no exchange, any row count works.
    x = _grid(1, 16, 16)
    want = _composed(lambda a: hdiff(a, 0.025), x, 3)
    np.testing.assert_allclose(np.asarray(fn(x)), want, rtol=1e-6, atol=1e-6)
