"""Property-based tests (hypothesis) for the IR graph analysis.

Invariants:
  * composing ops with radii r1 and r2 yields an inferred program radius of
    exactly r1 + r2 (footprint composition is a Minkowski sum);
  * the composed source footprint size never exceeds the product of the
    per-op footprint sizes (union over paths can only dedup);
  * graph-derived accounting is invariant under tap-weight values (costs
    come from structure, not numerics);
  * the reference lowering of a random affine pipeline preserves the input
    ring and matches a direct numpy convolution on the interior.
"""

import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.ir import StencilProgram, affine, lower_reference, repeat  # noqa: E402


def _star_taps(radius, weight=1.0):
    taps = {(0, 0): weight}
    for k in range(1, radius + 1):
        taps.update({(k, 0): weight, (-k, 0): weight, (0, k): weight, (0, -k): weight})
    return taps


def _chain(radii, weights=None):
    weights = weights or [1.0] * len(radii)
    ops = []
    src = "x"
    for i, (r, w) in enumerate(zip(radii, weights)):
        name = f"s{i}"
        ops.append(affine(name, src, _star_taps(r, w)))
        src = name
    return StencilProgram("chain", ["x"], ops)


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 3), st.integers(0, 3))
def test_composed_radius_is_sum(r1, r2):
    prog = _chain([r1, r2])
    assert prog.radius == r1 + r2
    assert prog.spec().radius == r1 + r2


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 2), min_size=1, max_size=4))
def test_composed_radius_is_sum_deep(radii):
    prog = _chain(radii)
    assert prog.radius == sum(radii)
    fp = prog.footprints()
    bound = 1
    for r in radii:
        bound *= len(_star_taps(r))
    assert 1 <= len(fp["x"]) <= bound


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 3), st.integers(1, 5))
def test_repeat_radius_scales_linearly(r, k):
    """Temporal blocking invariant: repeat(p, k).radius == k * p.radius
    (footprints compose by Minkowski sum, so radii add per sweep)."""
    prog = _chain([r])
    pk = repeat(prog, k)
    assert pk.radius == k * prog.radius
    assert pk.steps == k
    assert pk.spec().radius == k * r


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 3), st.floats(0.1, 4.0), st.floats(-4.0, -0.1))
def test_spec_is_structural_not_numeric(r, w1, w2):
    a = _chain([r], [w1]).spec()
    b = _chain([r], [w2]).spec()
    assert (a.macs, a.other_ops, a.reads, a.radius) == (b.macs, b.other_ops, b.reads, b.radius)


def _multifield(radii):
    """A program over len(radii) input fields: field i is star-smoothed at
    radius radii[i], and the smoothed fields are summed into the output (a
    scaled_residual over the non-base terms), so every field's composed
    footprint is exactly its own star."""
    from repro.ir import scaled_residual

    fields = [f"f{i}" for i in range(len(radii))]
    ops = [affine(f"s{i}", f, _star_taps(r)) for i, (f, r) in enumerate(zip(fields, radii))]
    if len(radii) == 1:
        ops.append(affine("out", "s0", {(0, 0): 1.0}))
    else:
        ops.append(
            scaled_residual("out", "s0", [(f"s{i}", 1) for i in range(1, len(radii))], 1.0)
        )
    return StencilProgram("multi", fields, ops)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 3), min_size=1, max_size=4))
def test_multifield_accounting_is_per_field_sum(radii):
    """Tentpole invariant: a multi-field program's total reads equal the
    per-field sum, its radius is the widest field's reach, and compulsory
    fused bytes count every field once (+ the output)."""
    prog = _multifield(radii)
    per_field = prog.reads_by_field()
    assert sum(per_field.values()) == prog.spec().reads
    for i, r in enumerate(radii):
        assert per_field[f"f{i}"] == len(_star_taps(r))
        assert prog.field_radius(f"f{i}") == r
    assert prog.radius == max(radii)
    points = 64
    assert prog.fused_bytes(points) == (len(radii) + 1) * points * 4


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 3))
def test_single_field_degenerates_to_scalar_accounting(r):
    """A one-field program answered through the per-field API must agree
    exactly with the classic scalar accounting — nothing drifts when the
    multi-field machinery is not in play."""
    prog = _chain([r])
    spec = prog.spec()
    assert prog.reads_by_field() == {"x": spec.reads}
    assert prog.field_radii() == {"x": spec.radius}
    multi = _multifield([r])  # same star through the multi-field builder
    assert multi.reads_by_field()["f0"] == spec.reads
    assert multi.field_radius("f0") == spec.radius


def _two_output(ra, rb):
    """A decoupled two-output program: field a evolves by its own star of
    radius ra, field b by its own star of radius rb — so each output's
    derived radius is exactly its own star's and composition cannot mix
    them."""
    ops = [
        affine("a_new", "a", _star_taps(ra)),
        affine("b_new", "b", _star_taps(rb)),
    ]
    return StencilProgram(
        "two_out", ["a", "b"], ops, outputs={"a": "a_new", "b": "b_new"}
    )


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2), st.integers(0, 2), st.integers(1, 4))
def test_multioutput_radii_scale_per_output_under_repeat(ra, rb, k):
    """Tentpole invariant: repeat(p, k) scales EVERY output's derived
    radius by k independently — output_radii()[f] == k * r_f — and the
    exchange radii (what the merged exchange moves and the wire model
    bills) follow the full chain radius for every evolving field."""
    prog = _two_output(ra, rb)
    assert prog.output_radii() == {"a": ra, "b": rb}
    pk = repeat(prog, k)
    assert pk.output_radii() == {"a": k * ra, "b": k * rb}
    assert pk.radius == k * max(ra, rb)
    ex = pk.exchange_radii()
    assert ex["a"] == ex["b"] == pk.radius


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2), st.integers(0, 2))
def test_multioutput_reads_are_per_field_sum(ra, rb):
    """A multi-output program's total §3.1 reads equal the per-field sum,
    and fused bytes count every input once plus every OUTPUT once."""
    prog = _two_output(ra, rb)
    per_field = prog.reads_by_field()
    assert sum(per_field.values()) == prog.spec().reads
    assert per_field == {"a": len(_star_taps(ra)), "b": len(_star_taps(rb))}
    points = 64
    assert prog.fused_bytes(points) == (2 + 2) * points * 4


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 2), min_size=1, max_size=3))
def test_explicit_single_output_is_degenerate(radii):
    """Declaring outputs={passthrough: last_op} explicitly must be
    indistinguishable from the legacy default — same fingerprint, equality,
    analysis — on a random affine chain."""
    prog = _chain(radii)
    explicit = StencilProgram(
        prog.name, prog.inputs, prog.ops, ndim=prog.ndim,
        outputs={prog.passthrough: prog.output},
    )
    assert explicit == prog
    assert explicit.fingerprint() == prog.fingerprint()
    assert hash(explicit) == hash(prog)
    assert explicit.outputs == prog.outputs
    assert explicit.exchange_radii() == prog.exchange_radii()
    assert explicit.spec() == prog.spec()


def test_single_output_degeneracy_all_conformance_programs():
    """Every pre-existing (single-output) conformance program is the strict
    degenerate case: outputs defaults to {passthrough: last op}, the
    explicit construction is fingerprint-identical, and the exchange radii
    reproduce the legacy rule (passthrough at full chain radius, every
    other field at its composed access radius)."""
    import sys
    from pathlib import Path

    sys.path.insert(0, str(Path(__file__).resolve().parent))
    from conformance import PROGRAMS

    single = {n: f for n, f in PROGRAMS.items() if len(PROGRAMS[n]().outputs) == 1}
    assert len(single) == 9
    for name, factory in single.items():
        prog = factory()
        assert prog.outputs == {prog.passthrough: prog.output}
        explicit = StencilProgram(
            prog.name, prog.inputs, prog.ops, ndim=prog.ndim,
            passthrough=prog.passthrough,
            outputs={prog.passthrough: prog.output},
        )
        assert explicit == prog, name
        legacy = dict(prog.field_radii())
        legacy[prog.passthrough] = prog.radius
        assert prog.exchange_radii() == legacy, name


@settings(max_examples=20, deadline=None)
@given(
    st.integers(1, 2),
    st.integers(0, 1000),
)
def test_reference_lowering_preserves_ring_and_interior(r, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((2, 9, 9)).astype(np.float32)
    prog = _chain([r])
    out = np.asarray(lower_reference(prog)(jnp.asarray(x)))
    # Ring passthrough.
    ring = np.ones((9, 9), bool)
    ring[r:-r, r:-r] = False
    np.testing.assert_array_equal(out[:, ring], x[:, ring])
    # Interior = star-sum oracle.
    want = np.zeros_like(x)
    for dr, dc in _star_taps(r):
        want[:, r:-r, r:-r] += x[:, r + dr : 9 - r + dr, r + dc : 9 - r + dc]
    np.testing.assert_allclose(out[:, r:-r, r:-r], want[:, r:-r, r:-r], rtol=1e-5, atol=1e-5)
