"""Cross-backend conformance matrix: the single source of parity truth.

One place defines the grid, tolerance, seed, program set, backend set, k
(temporal-blocking) set and mesh set; every parity test — tier-1 single
device and the 8-fake-device multidev jobs — draws its cells from here
instead of re-declaring its own grid/tolerance (what test_ir_lowering.py,
test_ir_temporal.py and tests/multidev/_ir_check.py each used to do).

The oracle for every cell is ``lower_reference`` of the k-step composed
program; the oracle itself is anchored against the hand-written kernels by
``test_conformance_matrix.py::test_oracle_matches_handwritten``.

Cells:
  program  in {hdiff, hdiff_simple} + the five elementary 2-D stencils
           + the two multi-field workloads {vadvc, hdiff_coupled}
           + the two multi-OUTPUT coupled systems {shallow_water,
             advection_diffusion} (results compared per output field)
  backend  in {reference, staged, pallas, sharded-reference, sharded-pallas}
  k        in {1, 2, 3}
  mesh     in {1x1, 8x1, 2x4, 1x8}   (rows x cols shards; non-sharded
                                      backends are mesh-independent and run
                                      at 1x1 only)

GRID is sized so every cell is feasible: 48 rows / 8 shards = 6 rows per
shard == the deepest chain halo in the matrix (hdiff / hdiff_coupled
radius 2, k = 3). Multi-field cells feed every backend the same
deterministic ``{field: array}`` mapping (``make_fields``).
"""

from __future__ import annotations

import functools

import numpy as np
import jax.numpy as jnp

from repro.ir import (
    advection_diffusion_program,
    hdiff_coupled_program,
    hdiff_program,
    jacobi2d_3pt_program,
    jacobi2d_5pt_program,
    jacobi2d_9pt_program,
    laplacian_program,
    lower_pallas,
    lower_reference,
    lower_sharded,
    repeat,
    seidel2d_program,
    shallow_water_program,
    smagorinsky_coeff,
    vadvc_program,
)

GRID = (2, 48, 48)
TOL = 1e-6
SEED = 2024

PROGRAMS = {
    "hdiff": lambda: hdiff_program(),
    "hdiff_simple": lambda: hdiff_program(limit=False),
    "jacobi2d_3pt": jacobi2d_3pt_program,
    "laplacian": laplacian_program,
    "jacobi2d_5pt": jacobi2d_5pt_program,
    "jacobi2d_9pt": jacobi2d_9pt_program,
    "seidel2d": seidel2d_program,
    # Multi-field workloads: every backend takes a {field: array} mapping.
    # vadvc exchanges BOTH fields' halos; hdiff_coupled's coeff field is
    # radius 0 at k=1 (no exchange) and grows to 2(k-1) under repeat.
    "vadvc": vadvc_program,
    "hdiff_coupled": lambda: hdiff_coupled_program(),
    # Multi-OUTPUT workloads (coupled systems): backends return a
    # {field: array} dict, compared per output field. shallow_water evolves
    # {u, v, h} through the gravity-wave coupling; advection_diffusion
    # evolves {c, u} over a SHARED radius-0 velocity v (growing to k-1).
    "shallow_water": shallow_water_program,
    "advection_diffusion": advection_diffusion_program,
}

BACKENDS = ("reference", "staged", "pallas", "sharded-reference", "sharded-pallas")
SHARDED_BACKENDS = tuple(b for b in BACKENDS if b.startswith("sharded-"))
KS = (1, 2, 3)
MESHES = ((1, 1), (8, 1), (2, 4), (1, 8))


def mesh_id(mesh_shape: tuple[int, int]) -> str:
    return f"{mesh_shape[0]}x{mesh_shape[1]}"


def devices_needed(backend: str, mesh_shape: tuple[int, int]) -> int:
    if backend in SHARDED_BACKENDS:
        return mesh_shape[0] * mesh_shape[1]
    return 1


def make_input(grid: tuple[int, ...] = GRID, seed: int = SEED):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(grid).astype(np.float32))


def make_fields(name: str, grid: tuple[int, ...] = GRID, seed: int = SEED):
    """The shared input for one program cell: a bare array for single-input
    programs (unchanged from before multi-field landed), a deterministic
    ``{field: array}`` mapping for multi-field ones. A ``coeff`` field gets
    a positive Smagorinsky-style coefficient (0.025 modulated per point)
    instead of raw noise, so repeated diffusion sweeps stay tame."""
    prog = PROGRAMS[name]()
    if len(prog.inputs) == 1:
        return make_input(grid, seed)
    rng = np.random.default_rng(seed)
    fields = {}
    for f in prog.inputs:
        a = rng.standard_normal(grid).astype(np.float32)
        if f == "coeff":
            a = smagorinsky_coeff(a)
        fields[f] = jnp.asarray(a)
    return fields


def iter_cases(mesh_shapes=MESHES):
    """All (program, backend, k, mesh) cells for the given mesh subset.
    Non-sharded backends are mesh-independent: they appear once, at 1x1."""
    for name in PROGRAMS:
        for backend in BACKENDS:
            for k in KS:
                for mesh_shape in mesh_shapes:
                    if backend not in SHARDED_BACKENDS and mesh_shape != (1, 1):
                        continue
                    yield name, backend, k, mesh_shape


# -- batched (ensemble) cells -------------------------------------------------
# The vmap-batched lowering's parity matrix: a deliberately small program
# subset (one single-input chain, one multi-output coupled system, one
# multi-field workload) because every batched cell already runs members x
# backends applications. The contract is two-sided: member i of the batched
# output is BIT-identical to an independent application on the SAME backend,
# and 1e-6-close to the reference oracle.
BATCHED_PROGRAMS = ("hdiff", "shallow_water", "hdiff_coupled")
BATCHED_KS = (1, 2)
BATCHED_MESHES = ((1, 1), (2, 4))
BATCH_MEMBERS = 3


def make_batched_fields(
    name: str, members: int = BATCH_MEMBERS,
    grid: tuple[int, ...] = GRID, seed: int = SEED,
):
    """Member i's initial conditions are ``make_fields(name, seed=SEED+i)``
    — each member is a genuinely distinct perturbation, and the SAME
    per-member inputs drive the unbatched side of every batched cell —
    stacked along a fresh leading member axis."""
    per = [make_fields(name, grid, seed + i) for i in range(members)]
    if isinstance(per[0], dict):
        return {f: jnp.stack([p[f] for p in per]) for f in per[0]}
    return jnp.stack(per)


def member_slice(result, i: int):
    """Member i of a batched result, dict-aware like :func:`to_host`."""
    if isinstance(result, dict):
        return {f: a[i] for f, a in result.items()}
    return result[i]


def build_batched(program, backend: str, mesh_shape: tuple[int, int]):
    """The batched ``{field: (N, *grid)} -> (N, ...)`` callable for one
    cell — same per-backend knobs as :func:`build` so "bit-exact vs the
    same backend" compares identical inner computations."""
    from repro.ir import lower_batched

    return lower_batched(
        program,
        backend=backend,
        mesh_shape=mesh_shape if backend in SHARDED_BACKENDS else None,
        interpret=True if backend == "pallas" else None,
    )


def run_batched_case(
    name: str, backend: str, k: int, mesh_shape, members: int = BATCH_MEMBERS
):
    """(batched, per_member_same_backend, per_member_oracle) for one cell;
    each of the last two is a list of ``members`` results."""
    prog = repeat(PROGRAMS[name](), k)
    batched = to_host(
        build_batched(prog, backend, mesh_shape)(
            make_batched_fields(name, members)
        )
    )
    base = build(prog, backend, mesh_shape)
    seq = [to_host(base(make_fields(name, GRID, SEED + i))) for i in range(members)]
    ref = lower_reference(prog)
    oracles = [
        to_host(ref(make_fields(name, GRID, SEED + i))) for i in range(members)
    ]
    return batched, seq, oracles


def assert_batched_case(
    name: str, backend: str, k: int, mesh_shape, members: int = BATCH_MEMBERS
):
    batched, seq, oracles = run_batched_case(name, backend, k, mesh_shape, members)
    tag = f"{name}/{backend}/k={k}/mesh={mesh_id(mesh_shape)}/N={members}"
    for i in range(members):
        got_i = member_slice(batched, i)
        assert_equal(got_i, seq[i], err_msg=f"{tag}/member={i} (vs same backend)")
        assert_close(got_i, oracles[i], err_msg=f"{tag}/member={i} (vs oracle)")
    return batched


def build(program, backend: str, mesh_shape: tuple[int, int], *, overlap=False):
    """The lowered ``x -> program(x)`` callable for one matrix cell."""
    if backend == "reference":
        return lower_reference(program)
    if backend == "staged":
        return lower_reference(program, mode="staged")
    if backend == "pallas":
        return lower_pallas(program, interpret=True)
    if backend in SHARDED_BACKENDS:
        return lower_sharded(
            program,
            mesh_shape=mesh_shape,
            inner=backend.removeprefix("sharded-"),
            overlap=overlap,
        )
    raise ValueError(f"unknown conformance backend {backend!r}")


def to_host(result):
    """A lowered result as numpy: a bare ndarray (single-output) or a
    ``{field: ndarray}`` dict (multi-output) — the one conversion every
    harness consumer shares."""
    if isinstance(result, dict):
        return {f: np.asarray(a) for f, a in result.items()}
    return np.asarray(result)


@functools.lru_cache(maxsize=None)
def oracle(name: str, k: int):
    """lower_reference of the k-step composed program on the shared input."""
    prog = repeat(PROGRAMS[name](), k)
    return to_host(lower_reference(prog)(make_fields(name)))


def run_case(name: str, backend: str, k: int, mesh_shape, *, overlap=False):
    """(got, want) for one cell; caller asserts (pytest or subprocess).
    Both sides are bare ndarrays for single-output programs and
    ``{field: ndarray}`` dicts for multi-output ones."""
    prog = repeat(PROGRAMS[name](), k)
    got = to_host(
        build(prog, backend, mesh_shape, overlap=overlap)(make_fields(name))
    )
    return got, oracle(name, k)


def assert_close(got, want, err_msg: str = ""):
    """Tolerance compare, per output field for multi-output results."""
    if isinstance(want, dict):
        assert set(got) == set(want), (
            f"{err_msg}: output fields {sorted(got)} != {sorted(want)}"
        )
        for f in want:
            np.testing.assert_allclose(
                got[f], want[f], rtol=TOL, atol=TOL, err_msg=f"{err_msg}[{f}]"
            )
        return
    np.testing.assert_allclose(got, want, rtol=TOL, atol=TOL, err_msg=err_msg)


def assert_equal(a, b, err_msg: str = ""):
    """Bitwise compare (the overlap contract), dict-aware like
    :func:`assert_close`."""
    if isinstance(a, dict):
        assert set(a) == set(b), (
            f"{err_msg}: output fields {sorted(a)} != {sorted(b)}"
        )
        for f in a:
            np.testing.assert_array_equal(a[f], b[f], err_msg=f"{err_msg}[{f}]")
        return
    np.testing.assert_array_equal(a, b, err_msg=err_msg)


def assert_case(name: str, backend: str, k: int, mesh_shape, *, overlap=False):
    got, want = run_case(name, backend, k, mesh_shape, overlap=overlap)
    assert_close(
        got,
        want,
        err_msg=f"{name}/{backend}/k={k}/mesh={mesh_id(mesh_shape)}"
        + ("/overlap" if overlap else ""),
    )
    return got


# -- gradient-conformance cells -----------------------------------------------
# The autodiff matrix: jax.grad of every differentiable lowering
# (``build_backend(..., differentiable=True)`` — the derived adjoint
# custom_vjp) must match jax.grad of ``lower_reference`` on a fixed
# random-weighted scalar loss, cell for cell over the SAME programs, ks and
# meshes as the forward matrix. The tolerance is RELATIVE: float32 gradient
# magnitudes grow with k (laplacian k=3 reaches ~60 absolute), so a flat
# atol would miss the ~1e-7 relative agreement the adjoints actually hold.
GRAD_TOL = 1e-5


def make_loss_weights(name: str, k: int):
    """Fixed random weights of the scalar conformance loss
    ``sum(w * y)`` (per output field for coupled systems) — shared by every
    backend cell so the oracle gradient is computed once."""
    ref = oracle(name, k)
    rng = np.random.default_rng(SEED + 7)
    if isinstance(ref, dict):
        return {
            f: jnp.asarray(rng.standard_normal(a.shape).astype(a.dtype))
            for f, a in ref.items()
        }
    return jnp.asarray(rng.standard_normal(ref.shape).astype(ref.dtype))


def grad_loss(fn, w):
    """The cell's scalar loss: fixed-weight contraction of the lowering."""
    import jax.numpy as _jnp

    def loss(x):
        y = fn(x)
        if isinstance(y, dict):
            return sum(_jnp.vdot(w[f], y[f]) for f in y)
        return _jnp.vdot(w, y)

    return loss


def build_grad(program, backend: str, mesh_shape: tuple[int, int]):
    """The differentiable lowered callable for one gradient cell."""
    from repro.ir import build_backend

    return build_backend(
        program,
        backend,
        mesh_shape=mesh_shape if backend in SHARDED_BACKENDS else None,
        interpret=True if backend == "pallas" else None,
        differentiable=True,
    )


@functools.lru_cache(maxsize=None)
def grad_oracle(name: str, k: int):
    """jax.grad of the reference lowering on the shared loss weights."""
    import jax

    prog = repeat(PROGRAMS[name](), k)
    w = make_loss_weights(name, k)
    g = jax.grad(grad_loss(lower_reference(prog), w))(make_fields(name))
    return to_host(g)


def run_grad_case(name: str, backend: str, k: int, mesh_shape):
    """(got, want) gradients for one cell, numpy on both sides."""
    import jax

    prog = repeat(PROGRAMS[name](), k)
    w = make_loss_weights(name, k)
    fn = build_grad(prog, backend, mesh_shape)
    got = jax.grad(grad_loss(fn, w))(make_fields(name))
    return to_host(got), grad_oracle(name, k)


def _assert_rel(got, want, err_msg: str):
    got, want = np.asarray(got), np.asarray(want)
    denom = max(float(np.abs(want).max()), 1e-30)
    err = float(np.abs(got - want).max()) / denom
    assert err <= GRAD_TOL, (
        f"{err_msg}: max relative gradient error {err:.3e} > {GRAD_TOL}"
    )


def assert_grad_close(got, want, err_msg: str = ""):
    """Relative-tolerance gradient compare, per input field for
    multi-field programs."""
    if isinstance(want, dict):
        assert set(got) == set(want), (
            f"{err_msg}: cotangent fields {sorted(got)} != {sorted(want)}"
        )
        for f in want:
            _assert_rel(got[f], want[f], f"{err_msg}[{f}]")
        return
    _assert_rel(got, want, err_msg)


def assert_grad_case(name: str, backend: str, k: int, mesh_shape):
    got, want = run_grad_case(name, backend, k, mesh_shape)
    assert_grad_close(
        got, want,
        err_msg=f"grad/{name}/{backend}/k={k}/mesh={mesh_id(mesh_shape)}",
    )
    return got
